"""L2: network definitions — the paper's two benchmark networks plus a
reduced variant for the build-time-trained end-to-end example.

A network is a list of ``LayerSpec`` + a parameter dict
``{layer_name: {"w": trits, "lo": i32, "hi": i32}}`` (classifier layers have
no thresholds). ``forward_int`` is the bit-exact inference path (backend
"ref" = pure jnp oracle, backend "pallas" = L1 kernels); it is what
``aot.py`` lowers to HLO for the Rust runtime, and what the Rust simulator
must match trit-for-trit.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from . import tcn_mapping
from .kernels import ref
from .kernels.ternary_conv import ternary_conv2d_pallas, ternary_dense_pallas
from .ternary import ternarize_acc


@dataclass(frozen=True)
class LayerSpec:
    """One CUTIE-schedulable layer.

    kind: "conv2d" (3x3, same padding, optional 2x2 max-pool, optional
    global max-pool), "tcn" (dilated causal 1D conv, run via the 2D
    mapping), or "dense" (classifier, raw logits).
    """

    name: str
    kind: str
    in_ch: int
    out_ch: int
    kernel: int = 3
    dilation: int = 1
    pool: bool = False
    global_pool: bool = False


@dataclass
class Network:
    name: str
    layers: List[LayerSpec]
    # Spatial/temporal geometry of the canonical input.
    input_hw: int = 32
    tcn_steps: int = 24
    classes: int = 10
    meta: Dict[str, str] = field(default_factory=dict)


def cifar9(channels: int = 96, name: Optional[str] = None) -> Network:
    """The paper's CIFAR-10 benchmark: 8 conv + 1 FC, 96 channels,
    max-pool after every second conv (32->16->8->4->2)."""
    c = channels
    layers = [LayerSpec("c1", "conv2d", 3, c)]
    for i in range(2, 9):
        layers.append(LayerSpec(f"c{i}", "conv2d", c, c, pool=(i % 2 == 0)))
    layers.append(LayerSpec("fc", "dense", 2 * 2 * c, 10))
    return Network(name or f"cifar9_{c}", layers, input_hw=32, classes=10)


def cifar9_mini() -> Network:
    """48-channel cifar9 for the build-time STE training run (cifar_e2e)."""
    return cifar9(channels=48, name="cifar9_mini")


def dvs_hybrid(channels: int = 96, classes: int = 12) -> Network:
    """The hybrid 2D-CNN + 1D-TCN DVS-gesture network ([6], §7): 5 conv
    layers collapsing 64x64x2 event frames into a 96-vector per time step,
    then 4 TCN layers (N=3, D = 1,2,4,8) + classifier over 24 stored steps."""
    cs = [32, 64, channels, channels, channels]
    layers = []
    in_c = 2
    for i, c in enumerate(cs, 1):
        layers.append(
            LayerSpec(f"c{i}", "conv2d", in_c, c, pool=True, global_pool=(i == 5))
        )
        in_c = c
    for i, d in enumerate([1, 2, 4, 8], 1):
        layers.append(LayerSpec(f"t{i}", "tcn", channels, channels, dilation=d))
    layers.append(LayerSpec("fc", "dense", channels, classes))
    return Network(f"dvs_hybrid_{channels}", layers, input_hw=64, classes=classes)


def cnn_part(net: Network) -> List[LayerSpec]:
    return [l for l in net.layers if l.kind == "conv2d"]


def tcn_part(net: Network) -> List[LayerSpec]:
    return [l for l in net.layers if l.kind in ("tcn", "dense")]


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _rand_trits(key, shape, zero_frac: float) -> jnp.ndarray:
    kz, ks = jax.random.split(key)
    nz = jax.random.bernoulli(kz, 1.0 - zero_frac, shape)
    sign = jax.random.bernoulli(ks, 0.5, shape).astype(jnp.int8) * 2 - 1
    return (nz.astype(jnp.int8) * sign).astype(jnp.int8)


def _fanin(spec: LayerSpec) -> int:
    if spec.kind == "conv2d":
        return spec.kernel * spec.kernel * spec.in_ch
    if spec.kind == "tcn":
        return 3 * spec.in_ch
    return spec.in_ch


def init_params(net: Network, seed: int = 0, zero_frac: float = 0.33) -> Dict:
    """Seeded random ternary parameters with controllable weight sparsity.

    Thresholds are set to +/- floor(0.5*sqrt(fanin * density)) so random
    inputs produce roughly balanced trits layer after layer — this keeps
    activity statistics realistic for the energy benchmarks even without
    training.
    """
    key = jax.random.PRNGKey(seed)
    params: Dict = {}
    for spec in net.layers:
        key, kw = jax.random.split(key)
        if spec.kind == "conv2d":
            shape = (spec.kernel, spec.kernel, spec.in_ch, spec.out_ch)
        elif spec.kind == "tcn":
            shape = (3, spec.in_ch, spec.out_ch)
        else:
            shape = (spec.in_ch, spec.out_ch)
        w = _rand_trits(kw, shape, zero_frac)
        entry = {"w": w}
        if spec.kind != "dense":
            th = max(1, int(0.5 * (_fanin(spec) * (1.0 - zero_frac)) ** 0.5))
            entry["lo"] = jnp.full((spec.out_ch,), -th, dtype=jnp.int32)
            entry["hi"] = jnp.full((spec.out_ch,), th, dtype=jnp.int32)
        params[spec.name] = entry
    return params


# ---------------------------------------------------------------------------
# Bit-exact integer forward (the inference contract)
# ---------------------------------------------------------------------------


def _conv_layer_int(x, spec: LayerSpec, p, backend: str):
    if backend == "pallas":
        acc = ternary_conv2d_pallas(x.astype(jnp.float32), p["w"].astype(jnp.float32))
    else:
        acc = ref.ternary_conv2d(x, p["w"])
    t = ternarize_acc(acc, p["lo"], p["hi"])
    if spec.pool:
        t = ref.maxpool2x2(t)
    if spec.global_pool:
        t = ref.global_maxpool(t)
    return t


def _tcn_layer_int(x, spec: LayerSpec, p, backend: str):
    """Dilated TCN layer via the offline 2D mapping (never via strided
    access — this is the artifact that runs on the 3x3 datapath)."""
    t_len = x.shape[0]
    z = tcn_mapping.map_input(x, spec.dilation)  # (R+1, D, Cin)
    w2d = tcn_mapping.map_weights(p["w"])  # (3, 3, Cin, Cout)
    if backend == "pallas":
        acc2d = ternary_conv2d_pallas(
            z.astype(jnp.float32), w2d.astype(jnp.float32)
        )
    else:
        acc2d = ref.ternary_conv2d(z, w2d)
    acc = tcn_mapping.unmap_output(acc2d, t_len, spec.dilation)
    return ternarize_acc(acc, p["lo"], p["hi"])


def forward_cnn_int(net: Network, params: Dict, frame, backend: str = "ref"):
    """2D front-end: (H, W, Cin) trits -> feature trits.

    For dvs_hybrid this ends in a (C,) per-time-step feature vector; for
    cifar9 it ends in the pre-classifier (2, 2, C) map.
    """
    x = frame
    for spec in cnn_part(net):
        x = _conv_layer_int(x, spec, params[spec.name], backend)
    return x


def forward_tcn_int(net: Network, params: Dict, seq, backend: str = "ref"):
    """Temporal back-end: (T, C) trits -> (classes,) int32 logits.
    Classification uses the last time step's features."""
    x = seq
    for spec in tcn_part(net):
        if spec.kind == "tcn":
            x = _tcn_layer_int(x, spec, params[spec.name], backend)
        else:
            feat = x[-1]
            if backend == "pallas":
                return ternary_dense_pallas(
                    feat.astype(jnp.float32),
                    params[spec.name]["w"].astype(jnp.float32),
                )
            return ref.ternary_dense(feat, params[spec.name]["w"])
    raise AssertionError("network has no classifier layer")


def forward_int(net: Network, params: Dict, x, backend: str = "ref"):
    """Full-network bit-exact inference.

    cifar9: x is one (32, 32, 3) trit image -> (10,) logits.
    dvs_hybrid: x is a (T, 64, 64, 2) trit frame stack -> (classes,) logits
    (the CNN is vmapped over time; in hardware the frames arrive
    sequentially and the TCN memory accumulates the feature vectors).
    """
    if any(l.kind == "tcn" for l in net.layers):
        feats = jax.vmap(lambda f: forward_cnn_int(net, params, f, backend))(x)
        return forward_tcn_int(net, params, feats, backend)
    feat = forward_cnn_int(net, params, x, backend)
    flat = feat.reshape(-1)
    p = params[net.layers[-1].name]
    if backend == "pallas":
        return ternary_dense_pallas(
            flat.astype(jnp.float32), p["w"].astype(jnp.float32)
        )
    return ref.ternary_dense(flat, p["w"])


def predict(net: Network, params: Dict, x, backend: str = "ref") -> int:
    """argmax with lowest-index tie-breaking (matches the Rust simulator)."""
    logits = forward_int(net, params, x, backend)
    return int(jnp.argmax(logits))
