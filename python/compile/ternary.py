"""Ternary quantization primitives shared by the L2 model and the trainer.

The bit-exact inference contract (mirrored by the Rust simulator, see
DESIGN.md §"Ternary semantics"):

  * trits are {-1, 0, +1}, carried as int8 (storage) / float32 (compute);
  * a convolution produces integer accumulators ``acc``;
  * ternarization uses two per-channel integer thresholds ``lo <= hi + 1``::

        out = +1  if acc > hi
              -1  if acc < lo
               0  otherwise

  * 2x2/2 max-pooling operates on ternarized outputs (max over trits);
  * the classifier layer keeps raw accumulators; argmax ties resolve to the
    lowest class index.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Threshold used on batchnorm-normalized pre-activations during training;
# folded into the integer (lo, hi) thresholds at export time.
ACT_DELTA = 0.5
# TWN-style weight ternarization threshold factor (Li & Liu, 2016).
WEIGHT_DELTA_FACTOR = 0.7


def ternarize_acc(acc: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """Two-threshold ternarization of integer accumulators.

    ``acc``: (..., C) int32; ``lo``/``hi``: (C,) int32 with lo <= hi.
    Returns int8 trits.
    """
    pos = (acc > hi).astype(jnp.int8)
    neg = (acc < lo).astype(jnp.int8)
    return pos - neg


@jax.custom_vjp
def ste_ternarize_weights(w: jnp.ndarray) -> jnp.ndarray:
    """TWN forward: w -> {-1,0,+1} with per-tensor threshold 0.7*mean|w|."""
    delta = WEIGHT_DELTA_FACTOR * jnp.mean(jnp.abs(w))
    return jnp.sign(w) * (jnp.abs(w) > delta).astype(w.dtype)


def _ste_w_fwd(w):
    return ste_ternarize_weights(w), None


def _ste_w_bwd(_, g):
    # Straight-through: gradient passes unchanged.
    return (g,)


ste_ternarize_weights.defvjp(_ste_w_fwd, _ste_w_bwd)


@jax.custom_vjp
def ste_ternarize_act(x: jnp.ndarray) -> jnp.ndarray:
    """Activation ternarization at +/-ACT_DELTA with hardtanh-style STE."""
    return (x > ACT_DELTA).astype(x.dtype) - (x < -ACT_DELTA).astype(x.dtype)


def _ste_a_fwd(x):
    return ste_ternarize_act(x), x


def _ste_a_bwd(x, g):
    # Clipped straight-through: pass gradient where |x| <= 1.
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_ternarize_act.defvjp(_ste_a_fwd, _ste_a_bwd)


def fold_bn_thresholds(mean: jnp.ndarray, var: jnp.ndarray, eps: float = 1e-5):
    """Fold a parameter-free batchnorm + +/-ACT_DELTA ternarization into the
    integer (lo, hi) thresholds of the inference contract.

    Training forward:  t = ternarize((acc - mean)/sqrt(var+eps) at +/-delta)
      +1  iff acc > mean + delta*sigma    -> hi = floor(mean + delta*sigma)
      -1  iff acc < mean - delta*sigma    -> lo = ceil (mean - delta*sigma)

    Returns (lo, hi) int32 arrays. Accumulators are integers, so
    ``acc > hi`` (int) == ``acc > mean + delta*sigma`` (float) whenever the
    float threshold is not itself an integer; exact-integer thresholds are a
    measure-zero training artifact and resolve consistently in both backends
    because both use the folded integer thresholds.
    """
    sigma = jnp.sqrt(var + eps)
    hi = jnp.floor(mean + ACT_DELTA * sigma).astype(jnp.int32)
    lo = jnp.ceil(mean - ACT_DELTA * sigma).astype(jnp.int32)
    # lo <= hi + 1 always holds (lo_f <= hi_f); lo == hi + 1 encodes an empty
    # zero-region, which is exact and unambiguous for integer accumulators.
    return lo, hi


def encode_input_image(img: jnp.ndarray, levels: int = 1) -> jnp.ndarray:
    """Encode a float image in [0, 1] into ternary input channels.

    Each source channel maps to ``levels`` ternary channels via a thermometer
    code with symmetric thresholds: channel k fires +1 above
    (k+1)/(levels+1) + margin, -1 below (k+1)/(levels+1) - margin.
    With levels=1 this is a simple sign encoding around 0.5.
    """
    chans = []
    for k in range(levels):
        center = (k + 1.0) / (levels + 1.0)
        margin = 0.5 / (levels + 1.0)
        pos = (img > center + margin).astype(jnp.int8)
        neg = (img < center - margin).astype(jnp.int8)
        chans.append(pos - neg)
    return jnp.concatenate(chans, axis=-1)
