"""AOT entry point: ``python -m compile.aot --out-dir ../artifacts``.

Emits everything the Rust side consumes:

  HLO text (the interchange format — jax>=0.5 serialized protos use 64-bit
  instruction ids that xla_extension 0.5.1 rejects; the text parser
  reassigns ids, see /opt/xla-example/README.md):

    cifar9_96.hlo.txt         full-network inference, ref backend
    cifar9_96_l1_pallas.hlo.txt  first CIFAR layer through the L1 Pallas
                              kernel (interpret=True), conv+threshold
    dvs_cnn_96.hlo.txt        DVS front-end: frame -> 96-feature vector
    dvs_tcn_96.hlo.txt        DVS back-end: (24, 96) window -> 12 logits
    cifar9_mini.hlo.txt       the build-time-trained E2E network

  Weights + manifests (.ttn + .json) for the Rust simulator, and
  test-vector bundles (inputs + expected outputs) so cargo test can verify
  bit-exactness without invoking Python.

All functions are lowered with the weights baked in as constants: the Rust
request path passes only activations.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import training
from .kernels.ternary_conv import ternary_conv2d_pallas
from .ternary import ternarize_acc
from .ttn import export_network, write_ttn


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked-in network weights are large dense
    # literals; the default printer elides them as "{...}", which the HLO
    # text parser silently accepts and mis-compiles.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def lower_to_file(fn, example_args, path: str) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def f32_spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def export_cifar(net, params, out_dir: str, tag: str) -> None:
    """Full net: (H, W, 3) f32 trits -> (10,) f32 logits."""

    def fwd(x):
        logits = M.forward_int(net, params, x.astype(jnp.int8))
        return (logits.astype(jnp.float32),)

    lower_to_file(fwd, [f32_spec(net.input_hw, net.input_hw, 3)], f"{out_dir}/{tag}.hlo.txt")


def export_cifar_l1_pallas(net, params, out_dir: str, tag: str) -> None:
    """First CIFAR layer via the L1 Pallas kernel: (32,32,3) -> (32,32,96)
    ternarized trits as f32. This is the fig6 peak-efficiency workload and
    the proof that the Pallas kernel lowers into a Rust-loadable artifact."""
    spec = net.layers[0]
    p = params[spec.name]
    w = p["w"].astype(jnp.float32)

    def fwd(x):
        acc = ternary_conv2d_pallas(x, w)
        t = ternarize_acc(acc, p["lo"], p["hi"])
        return (t.astype(jnp.float32),)

    lower_to_file(fwd, [f32_spec(net.input_hw, net.input_hw, 3)], f"{out_dir}/{tag}_l1_pallas.hlo.txt")


def export_dvs(net, params, out_dir: str, tag: str) -> None:
    """Front-end and back-end as separate executables; the Rust coordinator
    owns the TCN memory between them (mirrors the hardware)."""

    def cnn(frame):
        feat = M.forward_cnn_int(net, params, frame.astype(jnp.int8))
        return (feat.astype(jnp.float32),)

    def tcn(seq):
        logits = M.forward_tcn_int(net, params, seq.astype(jnp.int8))
        return (logits.astype(jnp.float32),)

    lower_to_file(cnn, [f32_spec(net.input_hw, net.input_hw, 2)], f"{out_dir}/{tag}_cnn.hlo.txt")
    lower_to_file(tcn, [f32_spec(net.tcn_steps, 96)], f"{out_dir}/{tag}_tcn.hlo.txt")


def export_testvecs(net, params, out_dir: str, tag: str, n: int = 4, seed: int = 7) -> None:
    """Seeded inputs + golden outputs so cargo test runs without Python."""
    key = jax.random.PRNGKey(seed)
    tensors = []
    is_tcn = any(l.kind == "tcn" for l in net.layers)
    for i in range(n):
        key, k = jax.random.split(key)
        if is_tcn:
            x = jax.random.randint(k, (net.tcn_steps, net.input_hw, net.input_hw, 2), -1, 2, dtype=jnp.int32).astype(jnp.int8)
        else:
            x = jax.random.randint(k, (net.input_hw, net.input_hw, 3), -1, 2, dtype=jnp.int32).astype(jnp.int8)
        logits = M.forward_int(net, params, x)
        tensors.append((f"in{i}", np.asarray(x, dtype=np.int8)))
        tensors.append((f"out{i}", np.asarray(logits, dtype=np.int32)))
    write_ttn(f"{out_dir}/testvec_{tag}.ttn", tensors)
    print(f"  wrote {out_dir}/testvec_{tag}.ttn")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=160)
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()

    # --- cifar9_96 (paper benchmark, seeded random ternary weights) ---
    print("[aot] cifar9_96")
    net = M.cifar9(96)
    params = M.init_params(net, seed=0, zero_frac=0.33)
    export_network(net, params, f"{args.out_dir}/cifar9_96.ttn", f"{args.out_dir}/cifar9_96.json")
    export_cifar(net, params, args.out_dir, "cifar9_96")
    export_cifar_l1_pallas(net, params, args.out_dir, "cifar9_96")
    export_testvecs(net, params, args.out_dir, "cifar9_96")

    # --- dvs_hybrid_96 ---
    print("[aot] dvs_hybrid_96")
    dnet = M.dvs_hybrid(96)
    dparams = M.init_params(dnet, seed=1, zero_frac=0.5)
    export_network(dnet, dparams, f"{args.out_dir}/dvs_hybrid_96.ttn", f"{args.out_dir}/dvs_hybrid_96.json")
    export_dvs(dnet, dparams, args.out_dir, "dvs_hybrid_96")
    export_testvecs(dnet, dparams, args.out_dir, "dvs_hybrid_96", n=2)

    # --- cifar9_mini: build-time STE training (E2E validation) ---
    print("[aot] cifar9_mini (STE training)")
    mnet = M.cifar9_mini()
    if args.skip_train:
        mparams = M.init_params(mnet, seed=2)
        loss_log, test_acc = [], -1.0
    else:
        mparams, loss_log, test_acc = training.train(mnet, steps=args.train_steps)
        print(f"  float-STE test accuracy: {test_acc:.3f}")
    export_network(mnet, mparams, f"{args.out_dir}/cifar9_mini.ttn", f"{args.out_dir}/cifar9_mini.json")
    export_cifar(mnet, mparams, args.out_dir, "cifar9_mini")
    export_testvecs(mnet, mparams, args.out_dir, "cifar9_mini")

    # Labeled eval set for the cifar_e2e example (integer-exact accuracy).
    kdata = jax.random.PRNGKey(99)
    imgs, labels = training.synth_image_dataset(kdata, 256, hw=mnet.input_hw)
    xs = np.asarray(training.encode_dataset(imgs), dtype=np.int8)
    int_acc = training.eval_int(mnet, mparams, jnp.asarray(xs), labels, limit=256) if not args.skip_train else -1.0
    write_ttn(
        f"{args.out_dir}/evalset_cifar9_mini.ttn",
        [("images", xs), ("labels", np.asarray(labels, dtype=np.int32))],
    )
    with open(f"{args.out_dir}/train_log.json", "w") as f:
        json.dump(
            {
                "net": mnet.name,
                "steps": args.train_steps,
                "loss_log": loss_log,
                "float_test_acc": test_acc,
                "int_test_acc": int_acc,
            },
            f,
            indent=1,
        )
    print(f"  integer-model eval accuracy: {int_acc:.3f}")
    print(f"[aot] done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
